"""Planner (repro.core.plan) + two-tier consts cache (repro.core.pipeline).

Contracts under test:
  * Variant.AUTO resolves deterministically under heuristic/autotune and
    is refused by fixed;
  * autotune picks the argmin of its measured timings, memoizes per
    (config-sans-variant, backend), and honors injected probes;
  * all three policies produce images allclose to the monolithic oracle;
  * repeated init_pipeline for one config hash recomputes nothing (memory
    tier) and the disk tier round-trips constants bit-exactly;
  * the resolved plan is stamped into bench + streaming telemetry.
"""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.bench import bench_callable
from repro.core import (CONSTS_CACHE_STATS, Modality, UltrasoundPipeline,
                        Variant, clear_consts_cache, config_hash,
                        init_pipeline, monolithic_pipeline_fn, plan_pipeline,
                        set_consts_cache_dir, tiny_config)
from repro.core import plan as plan_lib
from repro.data import synth_rf
from repro.launch.serve import serve_ultrasound_stream


@pytest.fixture(autouse=True)
def _fresh_planner_state():
    plan_lib.clear_autotune_memo()
    yield
    plan_lib.clear_autotune_memo()


# ---------------------------------------------------------------------------
# config hash
# ---------------------------------------------------------------------------


def test_config_hash_stable_and_sensitive():
    cfg = tiny_config()
    assert config_hash(cfg) == config_hash(tiny_config())
    assert config_hash(cfg) != config_hash(cfg.with_(variant=Variant.SPARSE))
    # exclude: the autotune memo key ignores the axis it searches over
    a = config_hash(cfg.with_(variant=Variant.CNN), exclude=("variant",))
    b = config_hash(cfg.with_(variant=Variant.DYNAMIC), exclude=("variant",))
    assert a == b
    with pytest.raises(KeyError):
        config_hash(cfg, exclude=("not_a_field",))


# ---------------------------------------------------------------------------
# plan policies
# ---------------------------------------------------------------------------


def test_fixed_policy_honors_variant_and_refuses_auto():
    cfg = tiny_config(variant=Variant.SPARSE)
    plan = plan_pipeline(cfg, policy="fixed")
    assert plan.variant == Variant.SPARSE
    assert plan.policy == "fixed"
    assert plan.backend == jax.default_backend()
    with pytest.raises(ValueError, match="fixed"):
        plan_pipeline(cfg.with_(variant=Variant.AUTO), policy="fixed")
    with pytest.raises(ValueError, match="policy"):
        plan_pipeline(cfg, policy="oracle")


def test_heuristic_auto_resolves_deterministically():
    cfg = tiny_config(variant=Variant.AUTO)
    p1 = plan_pipeline(cfg, policy="heuristic")
    p2 = plan_pipeline(cfg, policy="heuristic")
    assert p1 == p2
    assert p1.variant.concrete
    # this container is the gather-friendly CPU stand-in (paper GPU rows)
    assert p1.backend == "cpu"
    assert p1.variant == plan_lib.BACKEND_VARIANT_PREFERENCE["cpu"]
    assert p1.variant == Variant.DYNAMIC
    # explicit concrete variant wins over the registry under every policy
    p3 = plan_pipeline(tiny_config(variant=Variant.CNN), policy="heuristic")
    assert p3.variant == Variant.CNN and "explicit" in p3.provenance


def test_autotune_picks_argmin_of_injected_timings_and_memoizes():
    calls = []

    def fake_measure(cfg, variant, *, runs, warmup):
        calls.append(variant)
        return {Variant.DYNAMIC: 3.0, Variant.CNN: 1.0,
                Variant.SPARSE: 2.0}[variant]

    cfg = tiny_config(variant=Variant.AUTO)
    plan = plan_pipeline(cfg, policy="autotune", measure=fake_measure)
    assert plan.variant == Variant.CNN
    assert len(calls) == 3
    assert dict(plan.autotune_t_s) == {"dynamic": 3.0, "cnn": 1.0,
                                       "sparse": 2.0}
    # memoized: same config modulo variant, same backend -> no re-timing
    plan2 = plan_pipeline(cfg, policy="autotune", measure=fake_measure)
    assert plan2 == plan and len(calls) == 3
    # a geometry change invalidates the memo
    plan_pipeline(cfg.with_(nx=8), policy="autotune", measure=fake_measure)
    assert len(calls) == 6
    # so do different probe settings (2-run timings must not answer a
    # 5-run request)
    plan_pipeline(cfg, policy="autotune", measure=fake_measure,
                  autotune_runs=5)
    assert len(calls) == 9


def test_autotune_real_timings_on_cpu_pick_fastest_variant():
    """Acceptance: autotune's pick IS the best measured fixed variant."""
    cfg = tiny_config(variant=Variant.AUTO)
    plan = plan_pipeline(cfg, policy="autotune",
                         autotune_runs=2, autotune_warmup=1)
    timings = dict(plan.autotune_t_s)
    assert set(timings) == {"dynamic", "cnn", "sparse"}
    assert all(t > 0 for t in timings.values())
    assert plan.variant.value == min(timings, key=timings.get)


@pytest.mark.parametrize("policy", ["fixed", "heuristic", "autotune"])
def test_all_policies_allclose_to_monolithic_oracle(policy):
    base = tiny_config(n_f=8, modality=Modality.DOPPLER)
    cfg = base if policy == "fixed" else base.with_(variant=Variant.AUTO)
    measure = (lambda c, v, *, runs, warmup:
               {Variant.DYNAMIC: 1.0, Variant.CNN: 2.0,
                Variant.SPARSE: 3.0}[v])
    plan = plan_pipeline(cfg, policy=policy, measure=measure)
    pipe = UltrasoundPipeline(cfg, plan=plan)
    assert pipe.cfg.variant.concrete

    rf = jnp.asarray(synth_rf(pipe.cfg, seed=0))
    mono = jax.jit(monolithic_pipeline_fn(pipe.cfg))
    np.testing.assert_allclose(
        np.asarray(pipe(rf)), np.asarray(mono(pipe.consts, rf)),
        rtol=1e-5, atol=1e-6)


def test_auto_image_allclose_to_every_fixed_variant():
    """Acceptance: the planner changes speed, never the image."""
    cfg = tiny_config(n_f=8)
    auto = UltrasoundPipeline(cfg.with_(variant=Variant.AUTO),
                              policy="heuristic")
    rf = jnp.asarray(synth_rf(cfg, seed=1))
    img = np.asarray(auto(rf))
    for v in [Variant.DYNAMIC, Variant.CNN, Variant.SPARSE]:
        fixed = UltrasoundPipeline(cfg.with_(variant=v))
        np.testing.assert_allclose(
            img, np.asarray(fixed(rf)), rtol=1e-4, atol=1e-4,
            err_msg=f"AUTO image diverged from fixed {v.value}")


def test_pipeline_rejects_conflicting_plan_and_policy():
    cfg = tiny_config()
    plan = plan_pipeline(cfg, policy="fixed")
    with pytest.raises(ValueError, match="policy"):
        UltrasoundPipeline(cfg, plan=plan, policy="heuristic")
    # matching policy is redundant but legal
    assert UltrasoundPipeline(cfg, plan=plan, policy="fixed").plan is plan


def test_pipeline_rejects_plan_for_different_geometry():
    plan = plan_pipeline(tiny_config(), policy="fixed")
    with pytest.raises(ValueError, match="geometry"):
        UltrasoundPipeline(tiny_config(nx=8), plan=plan)
    # a plan built on an AUTO config matches the cfg it resolves
    cfg = tiny_config(variant=Variant.AUTO)
    auto_plan = plan_pipeline(cfg, policy="heuristic")
    assert auto_plan.matches(cfg)
    assert auto_plan.matches(auto_plan.concretize(cfg))


def test_pipeline_rejects_plan_conflicting_with_explicit_variant():
    cfg = tiny_config(variant=Variant.AUTO)
    plan = plan_pipeline(cfg, policy="heuristic")    # resolves DYNAMIC
    assert plan.variant == Variant.DYNAMIC
    with pytest.raises(ValueError, match="explicit"):
        UltrasoundPipeline(tiny_config(variant=Variant.SPARSE), plan=plan)
    # the AUTO config and the plan-resolved config both remain valid
    assert UltrasoundPipeline(cfg, plan=plan).cfg.variant == Variant.DYNAMIC


def test_explicit_exec_map_wins_over_plan_and_is_restamped():
    """An explicit cfg.exec_map (e.g. "map" to bound peak memory) must not
    be reverted by a plan recorded under a different mapping, and the
    telemetry stamp must reflect what actually runs."""
    from repro.core import BatchedExecutor
    cfg = tiny_config()                        # exec_map="vmap"
    plan = plan_pipeline(cfg, policy="fixed")
    eng = BatchedExecutor(cfg.with_(exec_map="map"), plan=plan)
    assert eng.cfg.exec_map == "map"
    assert eng.plan.exec_map == "map"
    assert eng.plan.variant == plan.variant    # rest of the plan survives


def test_auto_without_plan_resolves_via_heuristic():
    pipe = UltrasoundPipeline(tiny_config(variant=Variant.AUTO))
    assert pipe.plan.policy == "heuristic"
    assert pipe.cfg.variant.concrete
    assert pipe.jitted is pipe._fn          # public handle, same object


def test_init_pipeline_refuses_auto():
    with pytest.raises(ValueError, match="AUTO"):
        init_pipeline(tiny_config(variant=Variant.AUTO))


# ---------------------------------------------------------------------------
# consts cache
# ---------------------------------------------------------------------------


def test_consts_cache_memory_tier_skips_recompute():
    cfg = tiny_config(variant=Variant.CNN, nx=12)      # unique geometry
    clear_consts_cache()
    CONSTS_CACHE_STATS.reset()
    a = init_pipeline(cfg)
    assert CONSTS_CACHE_STATS.misses == 1
    b = init_pipeline(cfg)
    assert CONSTS_CACHE_STATS.misses == 1              # zero recomputation
    assert CONSTS_CACHE_STATS.mem_hits == 1
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    # returned dicts are caller-owned copies
    a.clear()
    assert set(init_pipeline(cfg)) == set(b)


def test_consts_cache_disk_tier_roundtrips_bit_exact(tmp_path):
    from repro.core import consts_cache_dir
    cfg = tiny_config(variant=Variant.SPARSE, nz=20)   # unique geometry
    prev = consts_cache_dir()
    set_consts_cache_dir(str(tmp_path))
    try:
        clear_consts_cache()
        CONSTS_CACHE_STATS.reset()
        fresh = init_pipeline(cfg)
        assert CONSTS_CACHE_STATS.misses == 1
        assert any(p.suffix == ".npz" for p in tmp_path.iterdir())

        clear_consts_cache(memory=True)                # simulate restart
        cached = init_pipeline(cfg)
        assert CONSTS_CACHE_STATS.disk_hits == 1
        assert CONSTS_CACHE_STATS.misses == 1          # no recompute
        assert set(cached) == set(fresh)
        for k in fresh:
            assert cached[k].dtype == fresh[k].dtype
            np.testing.assert_array_equal(cached[k], fresh[k])
    finally:
        set_consts_cache_dir(prev)


def test_consts_cache_shared_across_exec_map_and_read_only():
    cfg = tiny_config(variant=Variant.DYNAMIC, nz=28)  # unique geometry
    clear_consts_cache()
    CONSTS_CACHE_STATS.reset()
    a = init_pipeline(cfg)
    b = init_pipeline(cfg.with_(exec_map="map"))       # same constants
    assert CONSTS_CACHE_STATS.misses == 1
    assert CONSTS_CACHE_STATS.mem_hits == 1
    # cached buffers are shared across consumers -> mutation is refused
    with pytest.raises(ValueError):
        a["idx"][0] = 0
    assert b["idx"] is a["idx"]


def test_consts_cache_disabled_paths():
    cfg = tiny_config(variant=Variant.DYNAMIC, nx=20)
    clear_consts_cache()
    CONSTS_CACHE_STATS.reset()
    init_pipeline(cfg, cache=False)
    init_pipeline(cfg, cache=False)
    assert CONSTS_CACHE_STATS.misses == 0              # bypass counts nothing
    assert CONSTS_CACHE_STATS.mem_hits == 0


# ---------------------------------------------------------------------------
# plan-stamped telemetry
# ---------------------------------------------------------------------------


def test_bench_result_carries_plan_in_every_ndjson_row():
    cfg = tiny_config()
    pipe = UltrasoundPipeline(cfg)
    rf = jnp.asarray(synth_rf(cfg, seed=0))
    res = bench_callable("t", None, (pipe.consts, rf),
                         input_bytes=cfg.input_bytes, warmup=1, runs=3,
                         deadline_s=1.0, jitted=pipe.jitted, plan=pipe.plan)
    from repro.bench import bench_stages
    res.stage_breakdown = bench_stages(cfg, rf, runs=2)

    assert res.plan["variant"] == cfg.variant.value
    assert res.plan["backend"] == jax.default_backend()
    recs = [json.loads(line) for line in res.ndjson_lines()]
    assert {r["kind"] for r in recs} == {"summary", "sample", "stage"}
    for r in recs:
        assert r["plan"]["policy"] == "fixed"
        assert r["plan"]["variant"] == cfg.variant.value


def test_streaming_stats_carry_resolved_plan():
    cfg = tiny_config(variant=Variant.AUTO)
    stats = serve_ultrasound_stream(cfg, batch=2, n_batches=3, depth=1,
                                    policy="heuristic")
    plan = stats["plan"]
    assert plan["policy"] == "heuristic"
    assert Variant(plan["variant"]).concrete
    assert plan["exec_map"] == "vmap"
    assert "/auto/" not in stats["name"]               # name uses resolved cfg
