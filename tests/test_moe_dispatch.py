"""MoE dispatch variants — the paper's V1/V2/V3 taxonomy at LM scale.

With ample capacity (no drops) all three produce identical outputs; with
tight capacity, overflow tokens are dropped deterministically.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.config import Variant
from repro.models import moe
from repro.models.common import KeyGen


def _cfg(**kw):
    base = dict(n_experts=8, n_experts_per_tok=2, moe_d_ff=32, d_model=16,
                capacity_factor=8.0, param_dtype="float32",
                compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def _params(cfg, key):
    return moe.moe_params(KeyGen(key), cfg, jnp.float32)


@pytest.mark.parametrize("t", [64, 256])
def test_variants_equivalent_with_ample_capacity(key, rng, t):
    cfg = _cfg()
    params = _params(cfg, key)
    x = (rng.standard_normal((1, t, cfg.d_model)) * 0.5).astype(np.float32)
    outs = {}
    for v in Variant:
        if not v.concrete:          # AUTO is an ultrasound-planner token
            continue
        y, aux = moe.moe_apply(params, cfg.with_(moe_variant=v),
                               jnp.asarray(x))
        outs[v] = np.asarray(y)
        assert np.isfinite(outs[v]).all()
    np.testing.assert_allclose(outs[Variant.DYNAMIC], outs[Variant.CNN],
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(outs[Variant.DYNAMIC], outs[Variant.SPARSE],
                               rtol=2e-4, atol=2e-5)


def test_tight_capacity_drops_tokens(key, rng):
    cfg = _cfg(capacity_factor=0.25)
    params = _params(cfg, key)
    x = (rng.standard_normal((1, 128, cfg.d_model)) * 0.5).astype(
        np.float32)
    y, _ = moe.moe_apply(params, cfg.with_(moe_variant=Variant.DYNAMIC),
                         jnp.asarray(x))
    # some token outputs must be exactly zero (dropped, no shared experts)
    norms = np.linalg.norm(np.asarray(y)[0], axis=-1)
    assert (norms < 1e-7).any()
    assert (norms > 1e-7).any()


def test_capacity_rank_never_exceeds_capacity(key, rng):
    cfg = _cfg(capacity_factor=0.5)
    logits_idx = rng.integers(0, cfg.n_experts, (512, 2)).astype(np.int32)
    cap, rank, keep = moe.capacity_and_rank(cfg, jnp.asarray(logits_idx),
                                            512)
    rank_np, keep_np = np.asarray(rank), np.asarray(keep)
    assert (rank_np[keep_np] < cap).all()
    # kept slots are unique per expert
    idx_flat = logits_idx.reshape(-1)
    rank_flat = rank_np.reshape(-1)
    keep_flat = keep_np.reshape(-1)
    seen = set()
    for e, r, k in zip(idx_flat, rank_flat, keep_flat):
        if k:
            assert (e, r) not in seen
            seen.add((e, r))


def test_router_deterministic(key, rng):
    cfg = _cfg()
    params = _params(cfg, key)
    x = rng.standard_normal((32, cfg.d_model)).astype(np.float32)
    w1, i1, _ = moe.route(cfg, params["router"], jnp.asarray(x))
    w2, i2, _ = moe.route(cfg, params["router"], jnp.asarray(x))
    assert np.array_equal(np.asarray(i1), np.asarray(i2))
    assert np.array_equal(np.asarray(w1), np.asarray(w2))


@given(t=st.sampled_from([16, 32, 64]), seed=st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_shared_experts_always_applied(t, seed):
    cfg = _cfg(n_shared_experts=1, capacity_factor=0.01)  # drop ~all
    params = _params(cfg, jax.random.PRNGKey(seed))
    x = (np.random.default_rng(seed).standard_normal(
        (1, t, cfg.d_model)) * 0.5).astype(np.float32)
    y, _ = moe.moe_apply(params, cfg, jnp.asarray(x))
    norms = np.linalg.norm(np.asarray(y)[0], axis=-1)
    assert (norms > 1e-7).all()   # shared expert output survives drops
