"""Analysis tooling: the dry-run records parse and the reports render."""

import json
import os

import pytest

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                           "results")


def _load(name):
    path = os.path.join(RESULTS_DIR, name)
    if not os.path.exists(path):
        pytest.skip(f"{name} not generated (run the dry-run sweep)")
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("name", ["dryrun_baseline.json",
                                  "dryrun_optimized.json"])
def test_sweep_records_complete(name):
    recs = _load(name)
    lm = [r for r in recs if r["arch"] != "ultrasound-bmode-cnn-batch256"]
    cells = {(r["arch"], r["shape"], r["mesh"]) for r in lm}
    assert len(cells) >= 80, len(cells)         # 40 cells x 2 meshes
    bad = [r for r in lm if r["status"] not in ("ok", "skipped")]
    assert not bad, [(r["arch"], r["shape"], r["mesh"]) for r in bad]
    # every compiled record carries the three roofline terms
    for r in lm:
        if r["status"] == "ok":
            for k in ("t_compute", "t_memory", "t_collective"):
                assert r["roofline"][k] >= 0.0
            assert r["unknown_trip_loops"] == 0, r["arch"]


def test_skips_match_design_rules():
    recs = _load("dryrun_optimized.json")
    skipped = {(r["arch"], r["shape"]) for r in recs
               if r["status"] == "skipped" and r["mesh"] == "single"}
    expected = {(a, "long_500k") for a in [
        "qwen3-8b", "granite-3-8b", "llama3-405b", "qwen2-vl-2b",
        "deepseek-v2-236b", "granite-moe-3b-a800m",
        "seamless-m4t-large-v2"]}
    assert skipped == expected, skipped ^ expected


def test_roofline_report_renders():
    import sys
    repo_root = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                             ".."))
    if repo_root not in sys.path:  # `pytest tests/` has no cwd on sys.path
        sys.path.insert(0, repo_root)
    from benchmarks import roofline_report
    recs = _load("dryrun_optimized.json")
    table = roofline_report.render(recs, "single")
    assert table.count("\n") > 40
    assert "llama3-405b" in table
    mem = roofline_report.memory_table(recs, "single")
    assert "mamba2-130m" in mem
