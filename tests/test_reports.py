"""Analysis/report tooling on the stage graph: roofline, sweep
comparison, trend report.

The roofline tests run the real pipeline at tiny geometry — per-stage
HLO costing, calibrated peaks, the BenchResult stamp — and hold the
stamp to the schema CI enforces. The comparison/trend tests run on
synthetic artifacts so the verdict logic (faster / SLOWER / noise /
missing) is pinned without timing anything."""

import json
import os
import sys

import jax.numpy as jnp

from repro.bench import bench_callable, bench_stages
from repro.bench.schema import SchemaError, validate_record
from repro.core import UltrasoundPipeline, Variant, tiny_config
from repro.data import synth_rf

repo_root = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                         ".."))
if repo_root not in sys.path:  # `pytest tests/` has no cwd on sys.path
    sys.path.insert(0, repo_root)

from benchmarks import compare_sweeps, roofline_report  # noqa: E402


def _tiny_cfg():
    return tiny_config(variant=Variant.DYNAMIC)


def _peaks():
    # Small calibration shapes: the memoized result is shared by every
    # test in the process, and ratios (not absolutes) are under test.
    return roofline_report.calibrate_peaks(n=256, copy_mb=8, reps=2)


def test_calibrated_peaks_positive_and_memoized():
    a, b = _peaks(), _peaks()
    assert a.flops_per_s > 0 and a.bytes_per_s > 0
    assert b is a                                 # per-backend memo


def test_stage_costs_on_stage_graph():
    costs = roofline_report.stage_costs(_tiny_cfg())
    assert set(costs) == {"demod", "beamform", "bmode"}
    beam = costs["beamform"]
    assert beam.flops > 0 and beam.bytes_min > 0
    assert beam.gather_elems > 0       # the dynamic DAS gather


def test_stage_roofline_rows_schema_valid():
    cfg = _tiny_cfg()
    measured = {"demod": 1e-4, "beamform": 2e-3, "bmode": 1e-4}
    roof = roofline_report.stage_roofline(cfg, measured, peaks=_peaks())
    assert set(roof) == set(measured)
    for name, row in roof.items():
        assert row["t_roof_s"] > 0 and row["pct_roofline"] > 0
        assert row["bound"] in ("compute", "memory", "memory+gather")
    # Unmeasured stages are skipped, never invented.
    partial = roofline_report.stage_roofline(
        cfg, {"beamform": 2e-3}, peaks=_peaks())
    assert set(partial) == {"beamform"}
    # The stamp satisfies the summary-record schema end to end.
    rec = _summary_rec("x", 1.0, runs=[1.0, 1.1, 0.9])
    validate_record({**rec, "roofline": roof})
    try:
        validate_record({**rec, "roofline": {"demod": {"flops": 1.0}}})
    except SchemaError:
        pass
    else:
        raise AssertionError("truncated roofline row passed the schema")


def test_attach_roofline_stamps_bench_result():
    cfg = _tiny_cfg()
    pipe = UltrasoundPipeline(cfg)
    rf = jnp.asarray(synth_rf(cfg, seed=0))
    res = bench_callable("t", None, (pipe.consts, rf),
                         input_bytes=cfg.input_bytes, warmup=1, runs=2,
                         jitted=pipe.jitted, plan=pipe.plan)
    roofline_report.attach_roofline(res, cfg, peaks=_peaks())
    assert res.roofline is None        # no stage breakdown -> no stamp
    res.stage_breakdown = bench_stages(cfg, rf, runs=2)
    roofline_report.attach_roofline(res, cfg, peaks=_peaks())
    assert set(res.roofline) == {"demod", "beamform", "bmode"}
    summary = json.loads(res.ndjson_lines()[0])
    assert validate_record(summary) == "summary"
    assert summary["roofline"]["beamform"]["pct_roofline"] > 0


def test_roofline_render_markdown():
    roof = {"beamform": {"flops": 1e9, "bytes": 2e6, "bytes_min": 1e6,
                         "t_measured_s": 2e-3, "t_roof_s": 1e-3,
                         "pct_roofline": 0.5, "bound": "memory+gather"}}
    table = roofline_report.render(roof, title="cell")
    assert "### cell" in table and "beamform" in table
    assert " 50.0%" in table and "gather" in table


# ---------------------------------------------------------------------------
# compare_sweeps on synthetic artifacts
# ---------------------------------------------------------------------------

def _summary_rec(name, t, runs=None, roofline=None):
    rec = {"kind": "summary", "name": name, "t_avg_s": t, "fps": 1 / t,
           "mbps": 1.0, "joules_per_run_model": 0.0, "peak_mem_gb": 0.0,
           "runs": 3,
           "latency": {"n": 3, "mean_s": t, "std_s": 0.0, "p50_s": t,
                       "p95_s": t, "p99_s": t, "jitter_s": 0.0,
                       "budget_s": None, "miss_rate": 0.0},
           "ci": {"mean": t, "ci_lo": t, "ci_hi": t, "n_runs": 1,
                  "confidence": 0.95, "n_boot": 2000, "seed": 0,
                  "method": "kalibera-jones-bootstrap",
                  "run_means": [t]}}
    if runs is not None:
        rec["ci"].update(mean=sum(runs) / len(runs), ci_lo=min(runs),
                         ci_hi=max(runs), n_runs=len(runs),
                         run_means=list(runs))
    if roofline is not None:
        rec["roofline"] = roofline
    return rec


def test_compare_sweeps_verdicts(tmp_path):
    roof = {"beamform": {"flops": 1e9, "bytes": 2e6, "bytes_min": 1e6,
                         "t_measured_s": 2e-3, "t_roof_s": 1e-3,
                         "pct_roofline": 0.5, "bound": "memory"}}
    base = {r["name"]: r for r in [
        _summary_rec("fast2x", 2.0, runs=[2.0, 2.02, 1.98]),
        _summary_rec("noisy", 1.0, runs=[0.8, 1.0, 1.2]),
        _summary_rec("slower", 1.0, runs=[1.0, 1.02, 0.98]),
        _summary_rec("gone", 1.0)]}
    cur = {r["name"]: r for r in [
        _summary_rec("fast2x", 1.0, runs=[1.0, 1.01, 0.99],
                     roofline=roof),
        _summary_rec("noisy", 1.1, runs=[0.9, 1.1, 1.3]),
        _summary_rec("slower", 3.0, runs=[3.0, 3.05, 2.95])]}
    lines = compare_sweeps.compare(base, cur)
    table = "\n".join(lines)
    row = {line.split("|")[1].strip(): line for line in lines[2:]}
    assert "faster" in row["fast2x"] and "50%" in row["fast2x"]
    assert "noise" in row["noisy"]
    assert "SLOWER" in row["slower"]
    assert "missing" in row["gone"]
    assert "2.0" in row["fast2x"]                 # ~2x speedup ratio
    assert table.count("|") > 20


def test_compare_sweeps_row_runs_fallback():
    assert compare_sweeps.row_runs({"t_avg_s": 1.5}) == [1.5]
    assert compare_sweeps.row_runs(
        _summary_rec("x", 1.0, runs=[1.0, 2.0])) == [1.0, 2.0]


# ---------------------------------------------------------------------------
# trend report: history accumulation + HTML render
# ---------------------------------------------------------------------------

def test_trend_report_history_and_html(tmp_path):
    from benchmarks import trend_report

    baseline = {"results": [_summary_rec("cell_a", 1.0,
                                         runs=[1.0, 1.02, 0.98])],
                "multitenant": []}
    good = {"results": [_summary_rec("cell_a", 1.05,
                                     runs=[1.05, 1.08, 1.02])]}
    bad = {"results": [_summary_rec("cell_a", 9.0,
                                    runs=[9.0, 9.1, 8.9])]}

    hist = tmp_path / "hist.ndjson"
    cells = trend_report.collect_cells(baseline, good["results"], [],
                                       factor=2.0)
    assert [c["verdict"] for c in cells] == ["pass"]
    history = trend_report.append_history(str(hist), cells, ts=1.0,
                                          label="r1")
    assert len(history) == 1

    cells2 = trend_report.collect_cells(baseline, bad["results"], [],
                                        factor=2.0)
    assert [c["verdict"] for c in cells2] == ["FAIL"]
    history = trend_report.append_history(str(hist), cells2, ts=2.0,
                                          label="r2")
    assert len(history) == 2                      # accumulated on disk

    page = trend_report.render_html(cells2, history, factor=2.0,
                                    label="r2")
    assert "<svg" in page and "polyline" in page   # sparkline rendered
    assert "FAIL" in page and "cell_a" in page
    assert "entirely above" in page                # gate reason surfaced

    # A baseline cell with no current row renders as missing, not a
    # crash.
    cells3 = trend_report.collect_cells(baseline, [], [], factor=2.0)
    assert [c["verdict"] for c in cells3] == ["missing"]
    assert "missing" in trend_report.render_html(
        cells3, history, factor=2.0, label="r3")
